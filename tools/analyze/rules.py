"""repro-lint rules for the layer-wise serving core.

Each rule pins an invariant that once regressed silently (or nearly
did).  One line per rule; the long story lives in docs/ARCHITECTURE.md
"Invariants & analysis".

  PL001    no pl.program_id inside a pl.when body (kernels/)
  JIT001   no raw Python int shape/width crossing jax.jit un-bucketed
  SEAM001  Admission/Routing policies are read-only observers
  CFG001   every ServeConfig field is read by the backend set that
           owns it (no dead or cross-backend config)
  PHASE001 queue dispatches over request phase handle every live queue
  FAULT001 fault injection is default-off: fault params default to
           None and every fault-engine call is guarded
  OBS001   tracing is default-off: every tracer emission in the
           serving hot path is guarded (trace=False never pays)
  UNIT001  no cross-dimension (Blocks/Tokens/Bytes/LayerIdx/Seconds)
           arithmetic, comparison or call without a sanctioned
           units.py converter (dataflow engine: units.py here)
  MC001    no reachable illegal Phase transition or queue/phase
           divergence in the scheduler state machine (bounded model
           checker: statemachine.py here)
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

try:
    from tools.analyze.core import FileContext, Rule, Violation
    from tools.analyze import statemachine
    from tools.analyze import units as units_engine
except ImportError:  # run as a plain script: tools/analyze on sys.path
    from core import FileContext, Rule, Violation
    import statemachine
    import units as units_engine


def _attr_chain(node: ast.AST) -> str:
    """'pl.program_id' for Attribute(Name('pl'), 'program_id')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


# ------------------------------------------------------------------ PL001
class PL001NoProgramIdInWhen(Rule):
    """Pallas `pl.when` predicates a *body*; reading `pl.program_id`
    inside one gives grid-position-dependent control flow that the
    interpret-mode harness executes differently from compiled mode
    (see kernels/paged_prefill.py).  Read program ids at kernel top
    level and close over them."""

    rule_id = "PL001"
    description = "pl.program_id read inside a pl.when body"

    def interested(self, path: Path) -> bool:
        return path.suffix == ".py" and "kernels" in path.parts

    def check_file(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        defs: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)}
        bodies: List[ast.AST] = []
        for node in ast.walk(ctx.tree):
            # @pl.when(cond) decorating a def
            if isinstance(node, ast.FunctionDef):
                for dec in node.decorator_list:
                    if (isinstance(dec, ast.Call)
                            and isinstance(dec.func, ast.Attribute)
                            and dec.func.attr == "when"):
                        bodies.append(node)
            # pl.when(cond)(fn_or_lambda)
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Call)
                    and isinstance(node.func.func, ast.Attribute)
                    and node.func.func.attr == "when"
                    and node.args):
                target = node.args[0]
                if isinstance(target, ast.Lambda):
                    bodies.append(target.body)
                elif (isinstance(target, ast.Name)
                        and target.id in defs):
                    bodies.append(defs[target.id])
        for body in bodies:
            for sub in ast.walk(body):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr == "program_id"):
                    out.append(self.violation(
                        ctx, sub.lineno,
                        "pl.program_id read inside a pl.when body; "
                        "hoist it to kernel top level"))
        return out


# ----------------------------------------------------------------- JIT001
_TAINT_FUNCS = {"len", "int"}


class JIT001RawIntAcrossJit(Rule):
    """A raw Python int (literal, len(), or arithmetic thereof) passed
    as a traced argument to a jitted callable becomes part of the trace
    signature via its *value* only when static — otherwise every novel
    width is a silent retrace.  Route widths through `_bucket` /
    `_round_up` / `jnp.asarray`, or declare them static."""

    rule_id = "JIT001"
    description = "raw Python int crossing jax.jit without bucketing"

    def interested(self, path: Path) -> bool:
        return path.name in ("executor.py", "engine.py")

    # -- taint -------------------------------------------------------
    def _tainted(self, node: ast.AST,
                 env: Dict[str, List[Tuple[int, bool]]],
                 line: int) -> bool:
        if isinstance(node, ast.Constant):
            return isinstance(node.value, int) \
                and not isinstance(node.value, bool)
        if isinstance(node, ast.Name):
            hist = env.get(node.id, [])
            prior = [t for ln, t in hist if ln <= line]
            return prior[-1] if prior else False
        if isinstance(node, ast.BinOp):
            return self._tainted(node.left, env, line) \
                or self._tainted(node.right, env, line)
        if isinstance(node, ast.UnaryOp):
            return self._tainted(node.operand, env, line)
        if isinstance(node, ast.IfExp):
            return self._tainted(node.body, env, line) \
                or self._tainted(node.orelse, env, line)
        if isinstance(node, ast.Call):
            return isinstance(node.func, ast.Name) \
                and node.func.id in _TAINT_FUNCS
        return False

    # -- jitted callables ---------------------------------------------
    @staticmethod
    def _static_spec(call: ast.Call) -> Tuple[Set[int], Set[str]]:
        nums: Set[int] = set()
        names: Set[str] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnums":
                vals = kw.value.elts \
                    if isinstance(kw.value, ast.Tuple) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Constant):
                        nums.add(int(v.value))
            elif kw.arg == "static_argnames":
                vals = kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Constant):
                        names.add(str(v.value))
        return nums, names

    @staticmethod
    def _is_jit(node: ast.AST) -> bool:
        return _attr_chain(node).endswith("jax.jit") \
            or _attr_chain(node) == "jit"

    def _collect_jitted(self, tree: ast.Module) -> Dict[str, Dict]:
        """name -> {params, static_nums, static_names, offset}."""
        jitted: Dict[str, Dict] = {}
        method_params: Dict[str, List[str]] = {}
        for node in ast.walk(tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            params = [a.arg for a in node.args.args]
            method_params[node.name] = params
            for dec in node.decorator_list:
                # @functools.partial(jax.jit, static_argnums=...)
                if (isinstance(dec, ast.Call) and dec.args
                        and _attr_chain(dec.func).endswith("partial")
                        and self._is_jit(dec.args[0])):
                    nums, names = self._static_spec(dec)
                    jitted[node.name] = {
                        "params": params, "nums": nums,
                        "names": names,
                        "offset": 1 if params[:1] == ["self"] else 0}
                elif isinstance(dec, ast.Call) and self._is_jit(dec.func):
                    nums, names = self._static_spec(dec)
                    jitted[node.name] = {
                        "params": params, "nums": nums,
                        "names": names,
                        "offset": 1 if params[:1] == ["self"] else 0}
        # self._f = jax.jit(self._g, ...) / f = jax.jit(g, ...)
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and self._is_jit(node.value.func)
                    and len(node.targets) == 1):
                continue
            tgt = node.targets[0]
            name = tgt.attr if isinstance(tgt, ast.Attribute) else (
                tgt.id if isinstance(tgt, ast.Name) else None)
            if name is None or not node.value.args:
                continue
            nums, names = self._static_spec(node.value)
            wrapped = node.value.args[0]
            params: Optional[List[str]] = None
            offset = 0
            if isinstance(wrapped, ast.Attribute) \
                    and wrapped.attr in method_params:
                params = method_params[wrapped.attr]
                offset = 1 if params[:1] == ["self"] else 0
            jitted[name] = {"params": params, "nums": nums,
                            "names": names, "offset": offset}
        return jitted

    def check_file(self, ctx: FileContext) -> List[Violation]:
        jitted = self._collect_jitted(ctx.tree)
        if not jitted:
            return []
        out: List[Violation] = []
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            env: Dict[str, List[Tuple[int, bool]]] = {}
            for st in ast.walk(fn):
                tgt: Optional[ast.expr] = None
                val: Optional[ast.expr] = None
                if isinstance(st, ast.Assign) and len(st.targets) == 1:
                    tgt, val = st.targets[0], st.value
                elif isinstance(st, (ast.AnnAssign, ast.AugAssign)):
                    tgt, val = st.target, st.value
                if isinstance(tgt, ast.Name) and val is not None:
                    env.setdefault(tgt.id, []).append(
                        (st.lineno, self._tainted(val, env, st.lineno)))
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call):
                    continue
                name = call.func.attr \
                    if isinstance(call.func, ast.Attribute) else (
                        call.func.id
                        if isinstance(call.func, ast.Name) else None)
                if name not in jitted:
                    continue
                spec = jitted[name]
                for i, arg in enumerate(call.args):
                    idx = i + spec["offset"]
                    if idx in spec["nums"]:
                        continue
                    if spec["params"] is not None \
                            and idx < len(spec["params"]) \
                            and spec["params"][idx] in spec["names"]:
                        continue
                    if self._tainted(arg, env, call.lineno):
                        out.append(self.violation(
                            ctx, call.lineno,
                            f"raw Python int as traced arg {i} of "
                            f"jitted '{name}': bucket it "
                            "(_bucket/_round_up/jnp.asarray) or "
                            "declare it static"))
                for kw in call.keywords:
                    if kw.arg is None or kw.arg in spec["names"]:
                        continue
                    if spec["params"] is not None \
                            and kw.arg in spec["params"] \
                            and spec["params"].index(kw.arg) \
                            in spec["nums"]:
                        continue
                    if self._tainted(kw.value, env, call.lineno):
                        out.append(self.violation(
                            ctx, call.lineno,
                            f"raw Python int as traced kwarg "
                            f"'{kw.arg}' of jitted '{name}': bucket "
                            "it or declare it static"))
        return out


# ---------------------------------------------------------------- SEAM001
_READ_API = frozenset({
    # SchedulerCore observer surface
    "load_stats", "admit_eta", "cached_hint", "device_need",
    "resume_need", "in_flight", "occupancy",
    # block manager / prefix cache probes
    "match_prefix", "num_free", "layers_on", "allocation",
    "blocks_for_tokens", "request_blocks", "total_host_blocks",
    "reclaimable_blocks",
    # cost model queries
    "chunk_prefill_time", "prefill_time", "decode_step_time",
    "kv_bytes",
    # harmless pure container reads
    "get", "keys", "values", "items", "index", "copy",
})
_ROOT_PRESERVING = frozenset(
    {"enumerate", "sorted", "reversed", "list", "tuple", "iter"})


class SEAM001PolicyMutatesCore(Rule):
    """Admission/Routing policies are *observers*: they rank, they never
    mutate scheduler, block-manager, or request state.  A policy that
    writes through its arguments bypasses the core's accounting (the
    sanitizer's shadow model would flag it at runtime; this catches it
    at review time)."""

    rule_id = "SEAM001"
    description = "policy subclass mutates core/request state"

    @staticmethod
    def _is_policy(cls: ast.ClassDef) -> bool:
        for base in cls.bases:
            name = base.attr if isinstance(base, ast.Attribute) else (
                base.id if isinstance(base, ast.Name) else "")
            if name.endswith(("AdmissionPolicy", "RoutingPolicy")):
                return True
        return False

    def _rooted(self, node: ast.AST, roots: Set[str]) -> bool:
        if isinstance(node, ast.Name):
            return node.id in roots
        if isinstance(node, (ast.Attribute, ast.Subscript)):
            return self._rooted(node.value, roots)
        if isinstance(node, ast.Starred):
            return self._rooted(node.value, roots)
        return False  # calls/comprehensions/literals build fresh values

    def _check_method(self, ctx: FileContext, fn: ast.FunctionDef,
                      out: List[Violation]) -> None:
        roots: Set[str] = {
            a.arg for a in fn.args.args + fn.args.kwonlyargs
        } - {"self", "cls"}
        for node in ast.walk(fn):
            # propagate rootedness through aliases and loops
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) \
                            and self._rooted(node.value, roots):
                        roots.add(tgt.id)
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                if isinstance(it, ast.Call) and isinstance(
                        it.func, ast.Name) \
                        and it.func.id in _ROOT_PRESERVING:
                    src_rooted = any(
                        self._rooted(a, roots) for a in it.args)
                else:
                    src_rooted = self._rooted(it, roots)
                if src_rooted:
                    tgts = node.target.elts if isinstance(
                        node.target, ast.Tuple) else [node.target]
                    for t in tgts:
                        if isinstance(t, ast.Name):
                            roots.add(t.id)
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = node.targets if isinstance(
                    node, ast.Assign) else [node.target]
                for tgt in tgts:
                    if isinstance(tgt, (ast.Attribute, ast.Subscript)) \
                            and self._rooted(tgt.value, roots):
                        out.append(self.violation(
                            ctx, node.lineno,
                            "policy writes through its argument "
                            f"('{_attr_chain(tgt)[:40]}'): policies "
                            "are read-only observers"))
            elif isinstance(node, ast.Delete):
                for tgt in node.targets:
                    if self._rooted(tgt, roots):
                        out.append(self.violation(
                            ctx, node.lineno,
                            "policy deletes core state: policies are "
                            "read-only observers"))
            elif isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute):
                if self._rooted(node.func.value, roots) \
                        and node.func.attr not in _READ_API:
                    out.append(self.violation(
                        ctx, node.lineno,
                        f"policy calls '.{node.func.attr}(...)' on "
                        "core/request state — not in the read-only "
                        "observer API (see _READ_API in "
                        "tools/analyze/rules.py)"))

    def check_file(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef) and self._is_policy(node):
                for item in node.body:
                    if isinstance(item, ast.FunctionDef) \
                            and item.name != "__init__":
                        self._check_method(ctx, item, out)
        return out


# ----------------------------------------------------------------- CFG001
_SECTION_RE = re.compile(r"#\s*----\s*(?P<label>.*?)\s*-*\s*$")
_SIM_FILES = frozenset({"sim.py"})
_ENGINE_FILES = frozenset({"engine.py", "executor.py"})
_COMMON_FILES = frozenset({"scheduler.py", "router.py"})


class CFG001DeadOrMisplacedConfig(Rule):
    """Every ServeConfig field must be read by the backend set its
    section comment claims: shared fields somewhere in the serving
    core, `engine-only` fields in the engine set (and never in the
    sim), `sim-only` in the sim set (and never in the engine).  Dead
    config is how the two backends drift apart silently."""

    rule_id = "CFG001"
    description = "ServeConfig field unread or read by the wrong backend"
    project_wide = True

    @staticmethod
    def _fields(ctx: FileContext, cls: ast.ClassDef) -> List[
            Tuple[str, int, str]]:
        """(name, line, section) per field, section from markers."""
        section_at: Dict[int, str] = {}
        current = "shared"
        end = max(getattr(n, "end_lineno", n.lineno)
                  for n in cls.body)
        for ln in range(cls.lineno, end + 1):
            m = _SECTION_RE.search(ctx.lines[ln - 1]) \
                if ln <= len(ctx.lines) else None
            if m:
                label = m.group("label").lower()
                if "engine-only" in label:
                    current = "engine"
                elif "sim-only" in label:
                    current = "sim"
                else:
                    current = "shared"
            section_at[ln] = current
        out = []
        for st in cls.body:
            if isinstance(st, ast.AnnAssign) \
                    and isinstance(st.target, ast.Name):
                out.append((st.target.id, st.lineno,
                            section_at.get(st.lineno, "shared")))
        return out

    @staticmethod
    def _reads(ctx: FileContext, skip: Optional[ast.ClassDef]) -> Set[str]:
        inside = set()
        if skip is not None:
            inside = {id(n) for n in ast.walk(skip)}
        return {
            n.attr for n in ast.walk(ctx.tree)
            if isinstance(n, ast.Attribute)
            and isinstance(n.ctx, ast.Load)
            and id(n) not in inside}

    def check_project(
        self, ctxs: Sequence[FileContext]
    ) -> List[Violation]:
        cfg_ctx: Optional[FileContext] = None
        cfg_cls: Optional[ast.ClassDef] = None
        for ctx in ctxs:
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.ClassDef) \
                        and node.name == "ServeConfig":
                    cfg_ctx, cfg_cls = ctx, node
                    break
            if cfg_cls is not None:
                break
        if cfg_cls is None or cfg_ctx is None:
            return []
        sim_reads: Set[str] = set()
        engine_reads: Set[str] = set()
        common_reads: Set[str] = set()
        for ctx in ctxs:
            skip = cfg_cls if ctx is cfg_ctx else None
            if ctx.path.name in _SIM_FILES:
                sim_reads |= self._reads(ctx, skip)
            if ctx.path.name in _ENGINE_FILES:
                engine_reads |= self._reads(ctx, skip)
            if ctx.path.name in _COMMON_FILES:
                common_reads |= self._reads(ctx, skip)
        out: List[Violation] = []
        for name, line, section in self._fields(cfg_ctx, cfg_cls):
            everywhere = sim_reads | engine_reads | common_reads
            if section == "shared" and name not in everywhere:
                out.append(self.violation(
                    cfg_ctx, line,
                    f"shared field '{name}' is read by neither "
                    "backend nor the scheduler core: dead config "
                    "(or mark it backend-only)"))
            elif section == "engine":
                if name not in engine_reads:
                    out.append(self.violation(
                        cfg_ctx, line,
                        f"engine-only field '{name}' is never read "
                        "by the engine backend"))
                elif name in sim_reads:
                    out.append(self.violation(
                        cfg_ctx, line,
                        f"engine-only field '{name}' is also read by "
                        "the sim backend: move it to the shared "
                        "section"))
            elif section == "sim":
                if name not in sim_reads:
                    out.append(self.violation(
                        cfg_ctx, line,
                        f"sim-only field '{name}' is never read by "
                        "the sim backend"))
                elif name in engine_reads:
                    out.append(self.violation(
                        cfg_ctx, line,
                        f"sim-only field '{name}' is also read by "
                        "the engine backend: move it to the shared "
                        "section"))
        return out


# --------------------------------------------------------------- PHASE001
class PHASE001PartialPhaseDispatch(Rule):
    """Free/cancel/unwind paths dispatch a request by which live queue
    holds it.  A dispatch that tests some live queues but not all of
    them silently drops requests in the untested phase (the PAUSED
    queue was added after the cancel path — this rule exists so the
    next phase cannot repeat that near-miss).  Also checks PHASE_QUEUES
    itself stays total over the Phase enum."""

    rule_id = "PHASE001"
    description = "phase dispatch misses a live queue / enum member"
    project_wide = True

    @staticmethod
    def _find(ctxs: Sequence[FileContext]) -> Tuple[
            Optional[FileContext], Optional[ast.Assign],
            Tuple[str, ...], Set[str]]:
        """Locate PHASE_QUEUES / LIVE_QUEUES and the Phase enum."""
        host, pq_node = None, None
        live: Tuple[str, ...] = ()
        members: Set[str] = set()
        for ctx in ctxs:
            for node in ast.walk(ctx.tree):
                if isinstance(node, (ast.Assign, ast.AnnAssign)):
                    tgt = node.targets[0] if isinstance(
                        node, ast.Assign) else node.target
                    if not isinstance(tgt, ast.Name):
                        continue
                    if tgt.id == "PHASE_QUEUES":
                        host, pq_node = ctx, node
                    elif tgt.id == "LIVE_QUEUES" \
                            and isinstance(node.value,
                                           (ast.Tuple, ast.List)):
                        live = tuple(
                            e.value for e in node.value.elts
                            if isinstance(e, ast.Constant))
                elif isinstance(node, ast.ClassDef) \
                        and node.name == "Phase":
                    members = {
                        t.id for st in node.body
                        if isinstance(st, ast.Assign)
                        for t in st.targets
                        if isinstance(t, ast.Name)}
        return host, pq_node, live, members

    def check_project(
        self, ctxs: Sequence[FileContext]
    ) -> List[Violation]:
        host, pq_node, live, members = self._find(ctxs)
        if host is None or pq_node is None:
            return []
        out: List[Violation] = []
        # (a) PHASE_QUEUES total over the Phase enum
        value = pq_node.value
        if members and isinstance(value, ast.Dict):
            keyed = {
                k.attr for k in value.keys
                if isinstance(k, ast.Attribute)}
            for missing in sorted(members - keyed):
                out.append(self.violation(
                    host, pq_node.lineno,
                    f"PHASE_QUEUES has no entry for "
                    f"Phase.{missing}: map every enum member to "
                    "its queue"))
        # (b) live-queue dispatches in the defining file are total
        if not live:
            return out
        for fn in ast.walk(host.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            tested: Dict[str, int] = {}
            for node in ast.walk(fn):
                if not isinstance(node, ast.Compare):
                    continue
                if not any(isinstance(op, (ast.In, ast.NotIn))
                           for op in node.ops):
                    continue
                for comp in node.comparators:
                    if isinstance(comp, ast.Attribute) \
                            and comp.attr in live:
                        tested.setdefault(comp.attr, node.lineno)
            if len(tested) >= 2 and len(tested) < len(live):
                missing = sorted(set(live) - set(tested))
                out.append(self.violation(
                    host, min(tested.values()),
                    f"'{fn.name}' dispatches over live queues "
                    f"{sorted(tested)} but never tests "
                    f"{missing}: a request parked there is "
                    "silently skipped"))
        return out


# --------------------------------------------------------------- FAULT001
_FAULT_PARAMS = frozenset({"fault_plan", "faults"})


class FAULT001FaultHooksNotDefaultOff(Rule):
    """Fault injection must be UNREACHABLE without an explicitly
    installed `FaultPlan`: the fault-free arms of every benchmark and
    identity test are the baseline the paper's numbers compare against,
    so a fault hook that runs by default silently changes them.  Two
    checks: (a) any parameter named `fault_plan`/`faults` must default
    to None (opt-in, like the sanitizer); (b) any CALL through a
    `faults` attribute (e.g. `self.faults.poll(...)`) must sit under a
    guard that tests the attribute — an `if`/`while`/ternary whose
    condition mentions it, or an `and` chain where a preceding operand
    does.  Plain value reads (`fault_host_reserve` arithmetic, which is
    inert at 0) are exempt."""

    rule_id = "FAULT001"
    description = "fault hook reachable without an installed FaultPlan"

    def interested(self, path: Path) -> bool:
        return path.suffix == ".py"

    @staticmethod
    def _mentions_faults(node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and sub.attr == "faults":
                return True
            if isinstance(sub, ast.Name) and sub.id == "faults":
                return True
        return False

    @staticmethod
    def _is_faults_call(call: ast.Call) -> bool:
        node = call.func
        while isinstance(node, ast.Attribute):
            if node.attr == "faults":
                return True
            node = node.value
        return isinstance(node, ast.Name) and node.id == "faults"

    def _check_defaults(self, ctx: FileContext,
                        out: List[Violation]) -> None:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef,
                                   ast.AsyncFunctionDef, ast.Lambda)):
                continue
            a = fn.args
            pos = a.posonlyargs + a.args
            # defaults align with the TAIL of the positional params
            pad: List[Optional[ast.expr]] = \
                [None] * (len(pos) - len(a.defaults))
            for arg, dflt in zip(pos, pad + list(a.defaults)):
                if arg.arg in _FAULT_PARAMS and not (
                        isinstance(dflt, ast.Constant)
                        and dflt.value is None):
                    out.append(self.violation(
                        ctx, arg.lineno,
                        f"fault parameter '{arg.arg}' must default to "
                        "None: fault injection is opt-in, never "
                        "ambient"))
            for arg, kdflt in zip(a.kwonlyargs, a.kw_defaults):
                if arg.arg in _FAULT_PARAMS and not (
                        isinstance(kdflt, ast.Constant)
                        and kdflt.value is None):
                    out.append(self.violation(
                        ctx, arg.lineno,
                        f"fault parameter '{arg.arg}' must default to "
                        "None: fault injection is opt-in, never "
                        "ambient"))

    def _check_guards(self, ctx: FileContext,
                      out: List[Violation]) -> None:
        parents: Dict[int, ast.AST] = {}
        for node in ast.walk(ctx.tree):
            for child in ast.iter_child_nodes(node):
                parents[id(child)] = node
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and self._is_faults_call(node)):
                continue
            guarded = False
            cur: ast.AST = node
            while id(cur) in parents:
                parent = parents[id(cur)]
                if isinstance(parent, (ast.If, ast.While, ast.IfExp)) \
                        and cur is not parent.test \
                        and self._mentions_faults(parent.test):
                    guarded = True
                    break
                if isinstance(parent, ast.BoolOp) \
                        and isinstance(parent.op, ast.And):
                    before = parent.values[:parent.values.index(cur)] \
                        if cur in parent.values else parent.values
                    if any(self._mentions_faults(v) for v in before
                           if v is not cur):
                        guarded = True
                        break
                if isinstance(parent, (ast.FunctionDef,
                                       ast.AsyncFunctionDef,
                                       ast.ClassDef)):
                    # guards don't cross def/class boundaries (a lambda
                    # inside a guarded branch IS lexically guarded)
                    break
                cur = parent
            if not guarded:
                out.append(self.violation(
                    ctx, node.lineno,
                    "unguarded call through '.faults': test the "
                    "attribute first (`if self.faults is not None:`) "
                    "so fault-free runs never reach the hook"))

    def check_file(self, ctx: FileContext) -> List[Violation]:
        out: List[Violation] = []
        self._check_defaults(ctx, out)
        self._check_guards(ctx, out)
        return out


# ---------------------------------------------------------------- OBS001
class OBS001UnguardedTracerEmission(Rule):
    """Tracing must be ZERO-overhead when off: `SchedulerCore.tracer`
    is None unless `ServeConfig.trace` installed one, and tests pin
    trace=False runs bit-identical to untraced ones.  An emission call
    that isn't guarded crashes every untraced run (AttributeError on
    None) or — worse — forces an always-on tracer.  So inside the hot
    stack (src/repro/core, src/repro/serving) every CALL through a
    `tracer` attribute/name (`self.tracer.span(...)`,
    `core.tracer.finish(...)`) must sit under a guard that tests the
    tracer — an `if`/`while`/ternary whose condition mentions it, or an
    `and` chain where a preceding operand does — exactly FAULT001's
    contract for fault hooks.  Plain value reads (`core.tracer.events`
    passed to an exporter under a config test) are exempt."""

    rule_id = "OBS001"
    description = "unguarded tracer emission in the serving hot path"

    def interested(self, path: Path) -> bool:
        parts = path.parts
        return path.suffix == ".py" \
            and ("serving" in parts or "core" in parts)

    @staticmethod
    def _mentions_tracer(node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and sub.attr == "tracer":
                return True
            if isinstance(sub, ast.Name) and sub.id == "tracer":
                return True
        return False

    @staticmethod
    def _is_tracer_call(call: ast.Call) -> bool:
        node = call.func
        while isinstance(node, ast.Attribute):
            if node.attr == "tracer":
                return True
            node = node.value
        return isinstance(node, ast.Name) and node.id == "tracer"

    def check_file(self, ctx: FileContext) -> List[Violation]:
        parents: Dict[int, ast.AST] = {}
        for node in ast.walk(ctx.tree):
            for child in ast.iter_child_nodes(node):
                parents[id(child)] = node
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and self._is_tracer_call(node)):
                continue
            guarded = False
            cur: ast.AST = node
            while id(cur) in parents:
                parent = parents[id(cur)]
                if isinstance(parent, (ast.If, ast.While, ast.IfExp)) \
                        and cur is not parent.test \
                        and self._mentions_tracer(parent.test):
                    guarded = True
                    break
                if isinstance(parent, ast.BoolOp) \
                        and isinstance(parent.op, ast.And):
                    before = parent.values[:parent.values.index(cur)] \
                        if cur in parent.values else parent.values
                    if any(self._mentions_tracer(v) for v in before
                           if v is not cur):
                        guarded = True
                        break
                if isinstance(parent, (ast.FunctionDef,
                                       ast.AsyncFunctionDef,
                                       ast.ClassDef)):
                    # guards don't cross def/class boundaries
                    break
                cur = parent
            if not guarded:
                out.append(self.violation(
                    ctx, node.lineno,
                    "unguarded call through '.tracer': test it first "
                    "(`if self.tracer is not None:`) so trace=False "
                    "runs never reach the emission"))
        return out


# ----------------------------------------------------------------- UNIT001
class UNIT001CrossDimensionMixing(Rule):
    """Unit-dimension taint analysis over the `core/units.py`
    vocabulary (Blocks/Tokens/Bytes/LayerIdx/Seconds): dimensions
    harvested from annotations propagate through assignments,
    arithmetic, calls and returns, and any point where two KNOWN
    dimensions meet without a sanctioned converter is flagged. The
    dataflow engine lives in tools/analyze/units.py."""

    rule_id = "UNIT001"
    description = "cross-dimension arithmetic/call without a converter"
    project_wide = True

    def check_project(
        self, ctxs: Sequence[FileContext]
    ) -> List[Violation]:
        return units_engine.check_units(ctxs)


# ------------------------------------------------------------------ MC001
class MC001SchedulerStateMachine(Rule):
    """Bounded model checker for the scheduler request lifecycle:
    extracts the Phase writes and queue-membership operations from
    `serving/scheduler.py` by AST, exhaustively interleaves lifecycle
    events over a small abstract state space, and reports reachable
    illegal transitions or queue/phase divergence with the event trace
    that produces them. The explorer lives in
    tools/analyze/statemachine.py."""

    rule_id = "MC001"
    description = "reachable illegal scheduler transition or divergence"

    def interested(self, path: Path) -> bool:
        # any scheduler.py: the engine's completeness gate (class
        # SchedulerCore + PHASE_QUEUES + LIVE_QUEUES all present) keeps
        # it quiet on files that merely share the name — and lets the
        # lint_corpus twins exercise the checker when named directly.
        return path.name == "scheduler.py"

    def check_file(self, ctx: FileContext) -> List[Violation]:
        return statemachine.check_statemachine(ctx)


ALL_RULES: List[Rule] = [
    PL001NoProgramIdInWhen(),
    JIT001RawIntAcrossJit(),
    SEAM001PolicyMutatesCore(),
    CFG001DeadOrMisplacedConfig(),
    PHASE001PartialPhaseDispatch(),
    FAULT001FaultHooksNotDefaultOff(),
    OBS001UnguardedTracerEmission(),
    UNIT001CrossDimensionMixing(),
    MC001SchedulerStateMachine(),
]
