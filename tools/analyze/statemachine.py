"""MC001 engine: bounded model checker for the scheduler lifecycle.

Extracts the Phase-transition writes and queue-membership operations
from `serving/scheduler.py` BY AST (no import, no execution), then
exhaustively explores a small abstract configuration space — two model
requests, every scheduling axis both ways, preempt / cancel / kill /
shed events interleaved — against a declarative transition spec, and
reports every REACHABLE illegal transition or queue/phase divergence
together with the event trace that produces it.

The abstraction:

  state      per model request: (phase, set of queues it sits in),
             starting at the pseudo-phase NEW. Pool geometry, clocks
             and KV contents are abstracted away: conditions over them
             evaluate to "unknown" and fork BOTH ways (memoized per
             event application, so `self.sc.chunked` is one value
             within one pass — which covers every axis setting as a
             superset).
  events     the public SchedulerCore methods that transitively touch
             lifecycle state (phase writes or queue append/remove),
             interpreted abstractly from their AST — plus declarative
             driver events (submit / seat / prefill-done / finish /
             kill-restart) modeling what the engine, simulator and
             cluster do between core calls.
  loops      single-iteration abstraction: a `for r in <queue-ish>`
             forks over each request currently in the iterable (plus
             the empty path) and runs the body once — interleavings
             beyond one iteration are reached through repeated events.

What is checked:

  * every `r.phase = Phase.X` write against the ALLOWED edge set
    (e.g. PAUSED -> SHED without an unwind is illegal);
  * every `queue.remove(r)` actually has `r` in that queue;
  * at event end, a request sits in at most one queue, and the queue
    it sits in is PHASE_QUEUES[its phase] (a live phase with NO queue
    is legal: that is a request handed to the driver mid-admission);
  * event outcome contracts (cancel() must terminally cancel any
    live-queued request — the "cancel misses a queue" bug class).

Everything is deterministic: BFS over a sorted event list with
memoized per-(state, event, binding) application, so two runs on the
same file produce byte-identical reports and the shortest trace wins.
"""

from __future__ import annotations

import ast
from collections import deque
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

try:
    from tools.analyze.core import FileContext, Violation
except ImportError:  # run as a plain script: tools/analyze on sys.path
    from core import FileContext, Violation

RULE_ID = "MC001"

N_REQUESTS = 2
MAX_STATES = 4000
MAX_LEAVES = 512          # per event application
MAX_INLINE_DEPTH = 5

# Declarative transition spec: the legal Phase edges (NEW is the
# pre-submit pseudo-phase). Anything else reachable is a violation.
ALLOWED_EDGES: Dict[str, FrozenSet[str]] = {
    "NEW": frozenset({"QUEUED"}),
    "QUEUED": frozenset({"PREFILL", "DECODE", "CANCELLED", "SHED"}),
    "PREFILL": frozenset({"DECODE", "PAUSED", "CANCELLED", "QUEUED"}),
    "DECODE": frozenset({"FINISHED", "PAUSED", "CANCELLED", "QUEUED"}),
    "PAUSED": frozenset({"PREFILL", "DECODE", "CANCELLED", "QUEUED"}),
    "FINISHED": frozenset(),
    "CANCELLED": frozenset(),
    "SHED": frozenset(),
}

# Direct-invocation preconditions for extracted events: shed_request's
# documented contract is WAITING-only (admission-gate rejection), so
# the checker only fires it on QUEUED requests — calling it on running
# work through another event (the corpus twin's bug) is still explored
# and still illegal.
EVENT_PRECONDITIONS: Dict[str, str] = {"shed_request": "QUEUED"}

# Outcome contracts: after cancel(r) on a request that sat in a live
# queue, the request must be terminally CANCELLED.
OUTCOME_MUST_CANCEL = "cancel"

_QUEUE_OPS = ("append", "appendleft", "remove")

# abstract values ----------------------------------------------------------
UNKNOWN = ("unknown",)


def _union(qnames: FrozenSet[str], extras: Tuple[int, ...] = (),
           filtered: bool = False) -> tuple:
    return ("union", qnames, extras, filtered)


class _Extract:
    """AST-extracted model of one scheduler file."""

    def __init__(self, tree: ast.Module) -> None:
        self.phase_queues: Dict[str, str] = {}
        self.live_queues: Tuple[str, ...] = ()
        self.methods: Dict[str, ast.FunctionDef] = {}
        self.cls: Optional[ast.ClassDef] = None
        for node in tree.body:
            if isinstance(node, ast.Assign) and node.targets:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name):
                    if tgt.id == "PHASE_QUEUES":
                        self._read_phase_queues(node.value)
                    elif tgt.id == "LIVE_QUEUES":
                        self._read_live(node.value)
            elif isinstance(node, ast.AnnAssign) and isinstance(
                    node.target, ast.Name):
                if node.target.id == "PHASE_QUEUES" and node.value:
                    self._read_phase_queues(node.value)
                elif node.target.id == "LIVE_QUEUES" and node.value:
                    self._read_live(node.value)
            elif isinstance(node, ast.ClassDef) \
                    and node.name == "SchedulerCore":
                self.cls = node
                for item in node.body:
                    if isinstance(item, ast.FunctionDef):
                        self.methods[item.name] = item

    def _read_phase_queues(self, node: ast.AST) -> None:
        if not isinstance(node, ast.Dict):
            return
        for k, v in zip(node.keys, node.values):
            if isinstance(k, ast.Attribute) \
                    and isinstance(v, ast.Constant) \
                    and isinstance(v.value, str):
                self.phase_queues[k.attr] = v.value

    def _read_live(self, node: ast.AST) -> None:
        if isinstance(node, (ast.Tuple, ast.List)):
            self.live_queues = tuple(
                e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, str))

    @property
    def complete(self) -> bool:
        return bool(self.cls and self.phase_queues and self.live_queues)

    def lifecycle_methods(self) -> FrozenSet[str]:
        """Methods that TRANSITIVELY write phases or touch queues."""
        direct = set()
        calls: Dict[str, set] = {}
        for name, fn in self.methods.items():
            calls[name] = set()
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if isinstance(t, ast.Attribute) \
                                and t.attr == "phase":
                            direct.add(name)
                if isinstance(sub, ast.Call) and isinstance(
                        sub.func, ast.Attribute):
                    if sub.func.attr in _QUEUE_OPS:
                        direct.add(name)
                    if isinstance(sub.func.value, ast.Name) \
                            and sub.func.value.id == "self" \
                            and sub.func.attr in self.methods:
                        calls[name].add(sub.func.attr)
        touched = set(direct)
        changed = True
        while changed:
            changed = False
            for name, callees in calls.items():
                if name not in touched and callees & touched:
                    touched.add(name)
                    changed = True
        return frozenset(touched)


class _Explorer:
    """Deterministic BFS over the abstract state space."""

    def __init__(self, ctx: FileContext, ex: _Extract) -> None:
        self.ctx = ctx
        self.ex = ex
        self.queues = tuple(sorted(set(ex.phase_queues.values())))
        self.live_phases = frozenset(
            p for p, q in ex.phase_queues.items()
            if q in ex.live_queues)
        self.queue_of = dict(ex.phase_queues)
        self.phase_of_queue = {q: p for p, q in ex.phase_queues.items()}
        self.ops_methods = ex.lifecycle_methods()
        self.events = self._build_events()
        self.violations: Dict[Tuple[int, str], Violation] = {}
        self._app_cache: Dict[tuple, Tuple[tuple, ...]] = {}

    # ------------------------------------------------------------- events
    def _build_events(self) -> List[Tuple[str, object]]:
        events: List[Tuple[str, object]] = []
        pq = self.queue_of
        if "QUEUED" in pq:
            events.append(("submit", ("builtin", "NEW", None,
                                      "QUEUED", (pq["QUEUED"],))))
        if "PREFILL" in pq and "QUEUED" in pq:
            events.append(("seat", ("builtin", "QUEUED", "handed",
                                    "PREFILL", ())))
        if "DECODE" in pq and "PREFILL" in pq:
            events.append(("prefill_done", (
                "builtin", "PREFILL", "handed", "DECODE",
                (pq["DECODE"],))))
            events.append(("chunk_done", (
                "builtin", "PREFILL", pq["PREFILL"], "DECODE",
                (pq["DECODE"],))))
        if "FINISHED" in pq and "DECODE" in pq:
            events.append(("finish", (
                "builtin", "DECODE", pq["DECODE"], "FINISHED",
                (pq["FINISHED"],))))
        if "QUEUED" in pq:
            events.append(("kill_restart", (
                "builtin", "*live-queued*", None, "QUEUED",
                (pq["QUEUED"],))))
        for name in sorted(self.ops_methods):
            if name.startswith("_"):
                continue
            events.append((name, self.ex.methods[name]))
        return events

    # ------------------------------------------------------------ explore
    def run(self) -> List[Violation]:
        init = ((("NEW", frozenset()),) * N_REQUESTS)
        parents: Dict[tuple, Tuple[Optional[tuple], str]] = {
            init: (None, "")}
        todo = deque([init])
        seen = {init}
        while todo and len(seen) < MAX_STATES:
            state = todo.popleft()
            for label, spec in self.events:
                for binding in self._bindings(spec):
                    call = (f"{label}(r{binding})"
                            if binding is not None else f"{label}()")
                    trace = self._trace(parents, state) + call
                    nexts = self._apply(state, spec, binding, trace)
                    for ns in nexts:
                        if ns not in seen:
                            seen.add(ns)
                            parents[ns] = (state, call)
                            todo.append(ns)
        return sorted(self.violations.values(),
                      key=lambda v: (v.line, v.message))

    def _bindings(self, spec: object) -> List[Optional[int]]:
        if isinstance(spec, tuple):  # builtin: always per-request
            return list(range(N_REQUESTS))
        fn = spec
        params = [a.arg for a in fn.args.args[1:]]
        if params and params[0] == "r":
            return list(range(N_REQUESTS))
        return [None]

    def _trace(self, parents: Dict, state: tuple) -> str:
        steps: List[str] = []
        cur: Optional[tuple] = state
        while cur is not None:
            prev, label = parents[cur]
            if label:
                steps.append(label)
            cur = prev
        steps.reverse()
        return " -> ".join(steps) + (" -> " if steps else "")

    # ------------------------------------------------- event application
    def _apply(self, state: tuple, spec: object,
               binding: Optional[int], trace: str) -> Tuple[tuple, ...]:
        key = (state, id(spec), binding)
        if key in self._app_cache:
            return self._app_cache[key]
        if isinstance(spec, tuple):
            out = self._apply_builtin(state, spec, binding)
        else:
            out = self._apply_method(state, spec, binding, trace)
        self._app_cache[key] = out
        return out

    def _apply_builtin(self, state: tuple, spec: tuple,
                       binding: int) -> Tuple[tuple, ...]:
        _, pre_phase, pre_queue, post_phase, post_queues = spec
        phase, qs = state[binding]
        if pre_phase == "*live-queued*":
            if phase not in self.live_phases or not qs:
                return ()
        elif phase != pre_phase:
            return ()
        if pre_queue == "handed" and qs:
            return ()
        if pre_queue not in (None, "handed") and pre_queue not in qs:
            return ()
        return (self._set(state, binding, post_phase,
                          frozenset(post_queues)),)

    def _apply_method(self, state: tuple, fn: ast.FunctionDef,
                      binding: Optional[int],
                      trace: str) -> Tuple[tuple, ...]:
        if binding is not None:
            pre = EVENT_PRECONDITIONS.get(fn.name)
            if pre is not None and state[binding][0] != pre:
                return ()
        env: Dict[str, object] = {"__memo__": {}, "__lastop__": {}}
        params = [a.arg for a in fn.args.args[1:]]
        for i, p in enumerate(params):
            env[p] = ("req", binding) if i == 0 and binding is not None \
                else UNKNOWN
        interp = _Interp(self, trace)
        leaves = interp.exec_block(state, env, fn.body)
        out = []
        pre_live_q = binding is not None and bool(state[binding][1]) \
            and state[binding][0] in self.live_phases
        for st, en, _ctrl, _val in leaves:
            ok = self._check_end(st, en, fn, trace)
            if fn.name == OUTCOME_MUST_CANCEL and pre_live_q \
                    and st[binding][0] != "CANCELLED":
                self._flag(fn.lineno, "outcome", (
                    f"cancel() left a live-queued request "
                    f"un-cancelled (phase {st[binding][0]}) "
                    f"[trace: {trace}]"))
                ok = False
            if ok:
                out.append(st)
        return tuple(dict.fromkeys(out))

    # ------------------------------------------------------------ checks
    def _set(self, state: tuple, i: int, phase: str,
             qs: FrozenSet[str]) -> tuple:
        reqs = list(state)
        reqs[i] = (phase, qs)
        return tuple(reqs)

    def _flag(self, line: int, kind: str, message: str) -> None:
        key = (line, kind)
        if key not in self.violations:
            self.violations[key] = Violation(
                RULE_ID, self.ctx.path, line, message)

    def _check_end(self, state: tuple, env: Dict, fn: ast.FunctionDef,
                   trace: str) -> bool:
        lastop = env.get("__lastop__", {})
        ok = True
        for i, (phase, qs) in enumerate(state):
            line = lastop.get(i, fn.lineno)
            if len(qs) > 1:
                self._flag(line, f"multiqueue-r{i}", (
                    f"request r{i} ends {fn.name}() in "
                    f"{len(qs)} queues ({', '.join(sorted(qs))}) "
                    f"[trace: {trace}]"))
                ok = False
            for q in qs:
                want = self.phase_of_queue.get(q)
                if want is not None and want != phase:
                    self._flag(line, f"divergence-r{i}", (
                        f"queue/phase divergence: r{i} sits in "
                        f"'{q}' (the {want} queue) with phase "
                        f"{phase} after {fn.name}() "
                        f"[trace: {trace}]"))
                    ok = False
        return ok

    def check_transition(self, i: int, old: str, new: str, line: int,
                         trace: str) -> None:
        allowed = ALLOWED_EDGES.get(old)
        if allowed is not None and new not in allowed:
            self._flag(line, "edge", (
                f"illegal transition {old} -> {new} for r{i} "
                f"(spec allows {old} -> "
                f"{{{', '.join(sorted(allowed)) or 'nothing'}}}) "
                f"[trace: {trace}]"))

    def check_remove(self, i: int, q: str, present: bool, line: int,
                     trace: str) -> None:
        if not present:
            self._flag(line, "remove", (
                f"removes r{i} from '{q}' while not a member "
                f"[trace: {trace}]"))


class _Interp:
    """Abstract interpreter for one event application. Statement
    execution is monadic: every step maps a set of (state, env) paths
    to its successors; unknown conditions fork both ways with a
    per-application memo keyed on the expression's dump."""

    def __init__(self, xp: _Explorer, trace: str) -> None:
        self.xp = xp
        self.trace = trace
        self.n_leaves = 0

    # leaves: (state, env, ctrl, value); ctrl in fall/return/break/continue
    def exec_block(self, state: tuple, env: Dict,
                   stmts: Sequence[ast.stmt]) -> List[tuple]:
        paths = [(state, env)]
        done: List[tuple] = []
        for st in stmts:
            nxt: List[tuple] = []
            for s, e in paths:
                for leaf in self._stmt(s, e, st):
                    if leaf[2] == "fall":
                        nxt.append((leaf[0], leaf[1]))
                    else:
                        done.append(leaf)
            paths = nxt[:MAX_LEAVES]
            if not paths:
                break
        out = [(s, e, "fall", None) for s, e in paths]
        out.extend(done)
        return out[:MAX_LEAVES]

    # ------------------------------------------------------- statements
    def _stmt(self, state: tuple, env: Dict,
              st: ast.stmt) -> List[tuple]:
        if isinstance(st, ast.Return):
            if st.value is None:
                return [(state, env, "return", ("const", None))]
            return [(s, e, "return", v)
                    for s, e, v in self.eval(state, env, st.value)]
        if isinstance(st, ast.Break):
            return [(state, env, "break", None)]
        if isinstance(st, ast.Continue):
            return [(state, env, "continue", None)]
        if isinstance(st, ast.Assign):
            return self._assign(state, env, st)
        if isinstance(st, ast.AugAssign):
            return [(state, env, "fall", None)]
        if isinstance(st, ast.Expr):
            return [(s, e, "fall", None)
                    for s, e, _ in self.eval(state, env, st.value)]
        if isinstance(st, ast.If):
            out: List[tuple] = []
            for s, e, b in self.eval_bool(state, env, st.test):
                out.extend(self.exec_block(
                    s, e, st.body if b else st.orelse))
            return out
        if isinstance(st, (ast.For, ast.While)):
            return self._loop(state, env, st)
        if isinstance(st, ast.Try):
            out = []
            for leaf in self.exec_block(state, env, st.body):
                if leaf[2] == "fall":
                    out.extend(self.exec_block(
                        leaf[0], leaf[1], st.finalbody))
                else:
                    out.append(leaf)
            return out
        return [(state, env, "fall", None)]

    def _assign(self, state: tuple, env: Dict,
                st: ast.Assign) -> List[tuple]:
        tgt = st.targets[0]
        # r.phase = Phase.X  — the checked transition write
        if isinstance(tgt, ast.Attribute) and tgt.attr == "phase":
            out = []
            for s, e, base in self.eval(state, env, tgt.value):
                if base[0] != "req":
                    out.append((s, e, "fall", None))
                    continue
                i = base[1]
                new = self._phase_const(st.value)
                if new is None:
                    out.append((s, e, "fall", None))
                    continue
                self.xp.check_transition(
                    i, s[i][0], new, st.lineno, self.trace)
                e2 = self._note_op(e, i, st.lineno)
                out.append((self.xp._set(s, i, new, s[i][1]),
                            e2, "fall", None))
            return out
        # next((q for q in X if ...), default) — binding fork
        if isinstance(tgt, ast.Name) and isinstance(st.value, ast.Call) \
                and isinstance(st.value.func, ast.Name) \
                and st.value.func.id == "next" and st.value.args \
                and isinstance(st.value.args[0], ast.GeneratorExp):
            gen = st.value.args[0]
            out = []
            for s, e, src in self.eval(state, env, gen.generators[0].iter):
                members = self._members(s, src)
                dflt = ("const", None)
                e0 = dict(e)
                e0[tgt.id] = dflt
                out.append((s, e0, "fall", None))
                for m in members:
                    e1 = dict(e)
                    e1[tgt.id] = ("req", m)
                    out.append((s, e1, "fall", None))
            return out
        out = []
        for s, e, v in self.eval(state, env, st.value):
            e2 = dict(e)
            if isinstance(tgt, ast.Name):
                e2[tgt.id] = v
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                for el in tgt.elts:
                    if isinstance(el, ast.Name):
                        e2[el.id] = UNKNOWN
            out.append((s, e2, "fall", None))
        return out

    def _loop(self, state: tuple, env: Dict,
              st: ast.stmt) -> List[tuple]:
        """Single-iteration abstraction; break/continue end the loop."""
        entries: List[tuple] = []
        if isinstance(st, ast.For):
            for s, e, src in self.eval(state, env, st.iter):
                entries.append((s, dict(e), None))  # skip path
                if src[0] in ("queue", "union"):
                    for m in self._members(s, src):
                        e1 = dict(e)
                        if isinstance(st.target, ast.Name):
                            e1[st.target.id] = ("req", m)
                        entries.append((s, e1, "body"))
                else:
                    e1 = dict(e)
                    for n in ast.walk(st.target):
                        if isinstance(n, ast.Name):
                            e1[n.id] = UNKNOWN
                    entries.append((s, e1, "body"))
        else:  # While: test forks, body at most once
            for s, e, b in self.eval_bool(state, env, st.test):
                entries.append((s, dict(e), "body" if b else None))
        out: List[tuple] = []
        for s, e, mode in entries:
            if mode is None:
                out.append((s, e, "fall", None))
                continue
            for leaf in self.exec_block(s, e, st.body):
                if leaf[2] in ("fall", "break", "continue"):
                    out.append((leaf[0], leaf[1], "fall", None))
                else:
                    out.append(leaf)
        return out[:MAX_LEAVES]

    # ------------------------------------------------------ expressions
    def eval(self, state: tuple, env: Dict,
             node: ast.AST) -> List[tuple]:
        """-> list of (state, env, value)."""
        if isinstance(node, ast.Name):
            if node.id in env:
                return [(state, env, env[node.id])]
            return [(state, env, UNKNOWN)]
        if isinstance(node, ast.Constant):
            return [(state, env, ("const", node.value))]
        if isinstance(node, ast.Attribute):
            return self._attr(state, env, node)
        if isinstance(node, ast.Call):
            return self._call(state, env, node)
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
            return self._concat(state, env, node)
        if isinstance(node, (ast.Compare, ast.BoolOp, ast.UnaryOp)):
            return [(s, e, ("const", b))
                    for s, e, b in self.eval_bool(state, env, node)]
        if isinstance(node, ast.List) and not node.elts:
            return [(state, env, _union(frozenset()))]
        if isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            out = []
            for s, e, src in self.eval(
                    state, env, node.generators[0].iter):
                if src[0] in ("queue", "union"):
                    qn = frozenset([src[1]]) if src[0] == "queue" \
                        else src[1]
                    extras = () if src[0] == "queue" else src[2]
                    filt = bool(node.generators[0].ifs) or (
                        src[0] == "union" and src[3])
                    out.append((s, e, _union(qn, extras, filt)))
                else:
                    out.append((s, e, UNKNOWN))
            return out
        if isinstance(node, ast.IfExp):
            out = []
            for s, e, b in self.eval_bool(state, env, node.test):
                out.extend(self.eval(
                    s, e, node.body if b else node.orelse))
            return out
        return [(state, env, UNKNOWN)]

    def _attr(self, state: tuple, env: Dict,
              node: ast.Attribute) -> List[tuple]:
        if isinstance(node.value, ast.Name) \
                and node.value.id == "self" \
                and node.attr in self.xp.queues:
            return [(state, env, ("queue", node.attr))]
        if isinstance(node.value, ast.Name) \
                and node.value.id == "Phase":
            return [(state, env, ("phaseconst", node.attr))]
        out = []
        for s, e, base in self.eval(state, env, node.value):
            if base[0] == "req" and node.attr == "phase":
                out.append((s, e, ("phase", s[base[1]][0], base[1])))
            else:
                out.append((s, e, UNKNOWN))
        return out

    def _concat(self, state: tuple, env: Dict,
                node: ast.BinOp) -> List[tuple]:
        out = []
        for s, e, lv in self.eval(state, env, node.left):
            for s2, e2, rv in self.eval(s, e, node.right):
                merged = self._merge(lv, rv)
                out.append((s2, e2, merged))
        return out

    def _merge(self, a: tuple, b: tuple) -> tuple:
        def parts(v: tuple):
            if v[0] == "queue":
                return frozenset([v[1]]), (), False
            if v[0] == "union":
                return v[1], v[2], v[3]
            return None
        pa, pb = parts(a), parts(b)
        if pa is None or pb is None:
            return UNKNOWN
        return _union(pa[0] | pb[0], pa[1] + pb[1], pa[2] or pb[2])

    def _call(self, state: tuple, env: Dict,
              node: ast.Call) -> List[tuple]:
        func = node.func
        # id(r)
        if isinstance(func, ast.Name) and func.id == "id" \
                and len(node.args) == 1:
            return [(s, e, ("id", v[1]) if v[0] == "req" else UNKNOWN)
                    for s, e, v in self.eval(state, env, node.args[0])]
        # set(map(id, Q)) — membership snapshot
        if isinstance(func, ast.Name) and func.id == "set" \
                and len(node.args) == 1 \
                and isinstance(node.args[0], ast.Call) \
                and isinstance(node.args[0].func, ast.Name) \
                and node.args[0].func.id == "map" \
                and len(node.args[0].args) == 2:
            out = []
            for s, e, src in self.eval(
                    state, env, node.args[0].args[1]):
                out.append((s, e, ("idset", frozenset(
                    self._members(s, src)))))
            return out
        # list(X) passes X through
        if isinstance(func, ast.Name) and func.id == "list" \
                and len(node.args) == 1:
            return self.eval(state, env, node.args[0])
        # self.<method>(...) — inline lifecycle methods
        if isinstance(func, ast.Attribute) \
                and isinstance(func.value, ast.Name) \
                and func.value.id == "self" \
                and func.attr in self.xp.ex.methods:
            return self._self_call(state, env, node, func.attr)
        # queue mutation: <queue-ish>.append/remove(r)
        if isinstance(func, ast.Attribute) and func.attr in _QUEUE_OPS:
            return self._queue_op(state, env, node, func)
        # anything else: evaluate args (unions pass through), unknown
        out = [(state, env, [])]
        for a in node.args:
            nxt = []
            for s, e, acc in out:
                for s2, e2, v in self.eval(s, e, a):
                    nxt.append((s2, e2, acc + [v]))
            out = nxt[:MAX_LEAVES]
        res = []
        for s, e, vals in out:
            merged: Optional[tuple] = None
            for v in vals:
                if v[0] in ("queue", "union"):
                    merged = v if merged is None \
                        else self._merge(merged, v)
            res.append((s, e, merged if merged is not None else UNKNOWN))
        return res

    def _self_call(self, state: tuple, env: Dict, node: ast.Call,
                   name: str) -> List[tuple]:
        if name not in self.xp.ops_methods:
            # no lifecycle effects: args still flow (unions propagate)
            return self._call(state, env, ast.Call(
                func=ast.Name(id="__opaque__", ctx=ast.Load()),
                args=node.args, keywords=node.keywords)) \
                if node.args else [(state, env, UNKNOWN)]
        depth = env.get("__depth__", 0)
        if not isinstance(depth, int) or depth >= MAX_INLINE_DEPTH:
            return [(state, env, UNKNOWN)]
        fn = self.xp.ex.methods[name]
        params = [a.arg for a in fn.args.args[1:]]
        # evaluate actual args left-to-right
        paths = [(state, env, [])]
        for a in node.args:
            nxt = []
            for s, e, acc in paths:
                for s2, e2, v in self.eval(s, e, a):
                    nxt.append((s2, e2, acc + [v]))
            paths = nxt[:MAX_LEAVES]
        out = []
        for s, e, vals in paths:
            cenv: Dict[str, object] = {
                "__memo__": e["__memo__"],
                "__lastop__": e["__lastop__"],
                "__depth__": depth + 1,
            }
            for i, p in enumerate(params):
                cenv[p] = vals[i] if i < len(vals) else UNKNOWN
            for leaf in self.exec_block(s, cenv, fn.body):
                # effects persist; caller env survives with callee memo
                e2 = dict(e)
                e2["__memo__"] = leaf[1]["__memo__"]
                e2["__lastop__"] = leaf[1]["__lastop__"]
                val = leaf[3] if leaf[2] == "return" else ("const", None)
                out.append((leaf[0], e2, val))
        return out[:MAX_LEAVES]

    def _queue_op(self, state: tuple, env: Dict, node: ast.Call,
                  func: ast.Attribute) -> List[tuple]:
        out = []
        for s, e, target in self.eval(state, env, func.value):
            argpaths = [(s, e, UNKNOWN)]
            if node.args:
                argpaths = self.eval(s, e, node.args[0])
            for s2, e2, arg in argpaths:
                if arg[0] != "req":
                    out.append((s2, e2, UNKNOWN))
                    continue
                i = arg[1]
                if target[0] == "queue":
                    q = target[1]
                    phase, qs = s2[i]
                    e3 = self._note_op(e2, i, node.lineno)
                    if func.attr == "remove":
                        self.xp.check_remove(
                            i, q, q in qs, node.lineno, self.trace)
                        s3 = self.xp._set(s2, i, phase, qs - {q})
                    else:
                        s3 = self.xp._set(s2, i, phase, qs | {q})
                    out.append((s3, e3, UNKNOWN))
                elif target[0] == "union" and func.attr != "remove":
                    # append to a local copy: track the binding
                    new = _union(target[1], target[2] + (i,),
                                 target[3])
                    e3 = dict(e2)
                    if isinstance(func.value, ast.Name):
                        e3[func.value.id] = new
                    out.append((s2, e3, UNKNOWN))
                else:
                    out.append((s2, e2, UNKNOWN))
        return out

    # -------------------------------------------------------- booleans
    def eval_bool(self, state: tuple, env: Dict,
                  node: ast.AST) -> List[tuple]:
        """-> list of (state, env, bool)."""
        if isinstance(node, ast.UnaryOp) and isinstance(
                node.op, ast.Not):
            return [(s, e, not b)
                    for s, e, b in self.eval_bool(
                        state, env, node.operand)]
        if isinstance(node, ast.BoolOp):
            is_and = isinstance(node.op, ast.And)
            paths = [(state, env, is_and)]
            for v in node.values:
                nxt = []
                for s, e, acc in paths:
                    if acc != is_and:       # already short-circuited
                        nxt.append((s, e, acc))
                        continue
                    nxt.extend(self.eval_bool(s, e, v))
                paths = nxt[:MAX_LEAVES]
            return paths
        if isinstance(node, ast.Compare):
            if len(node.ops) == 1:
                got = self._compare(state, env, node)
                if got is not None:
                    return got
            return self._fork(state, env, node)
        if isinstance(node, ast.UnaryOp):
            # non-`not` unary (e.g. -x) in a boolean context: numeric,
            # unknowable here — fork. MUST not bounce back through
            # eval(), which routes UnaryOp to eval_bool again.
            return self._fork(state, env, node)
        out = []
        for s, e, v in self.eval(state, env, node):
            t = self._truthy(s, v)
            if t is not None:
                out.append((s, e, t))
            else:
                out.extend(self._fork(s, e, node))
        return out

    def _compare(self, state: tuple, env: Dict,
                 node: ast.Compare) -> Optional[List[tuple]]:
        op = node.ops[0]
        out: List[tuple] = []
        decided = True
        for s, e, lv in self.eval(state, env, node.left):
            for s2, e2, rv in self.eval(s, e, node.comparators[0]):
                val = self._cmp_value(s2, op, lv, rv)
                if val is None:
                    decided = False
                    out.extend(self._fork(s2, e2, node))
                else:
                    out.append((s2, e2, val))
        return out if out and (decided or out) else None

    def _cmp_value(self, state: tuple, op: ast.cmpop, lv: tuple,
                   rv: tuple) -> Optional[bool]:
        if isinstance(op, (ast.In, ast.NotIn)):
            if lv[0] == "req" and rv[0] in ("queue", "union"):
                got = lv[1] in self._members(state, rv)
                return got if isinstance(op, ast.In) else not got
            if lv[0] == "id" and rv[0] == "idset":
                got = lv[1] in rv[1]
                return got if isinstance(op, ast.In) else not got
            return None
        if isinstance(op, (ast.Is, ast.Eq, ast.IsNot, ast.NotEq)):
            neg = isinstance(op, (ast.IsNot, ast.NotEq))
            if lv[0] == "phase" and rv[0] == "phaseconst":
                got = lv[1] == rv[1]
                return got != neg
            if lv[0] == "const" and rv[0] == "const":
                got = lv[1] is rv[1] if isinstance(
                    op, (ast.Is, ast.IsNot)) else lv[1] == rv[1]
                return got != neg
            if rv == ("const", None) and lv[0] in (
                    "req", "queue", "union", "idset", "phase"):
                return neg  # a bound value is never None
            if lv == ("const", None) and rv[0] in (
                    "req", "queue", "union", "idset", "phase"):
                return neg
        return None

    def _truthy(self, state: tuple, v: tuple) -> Optional[bool]:
        if v[0] == "const":
            return bool(v[1])
        if v[0] in ("req", "id", "phase"):
            return True
        if v[0] == "queue":
            return bool(self._members(state, v))
        if v[0] == "union":
            members = self._members(state, v)
            if not members:
                return False
            return None if v[3] else True  # filtered: may be empty
        if v[0] == "idset":
            return bool(v[1])
        return None

    def _fork(self, state: tuple, env: Dict,
              node: ast.AST) -> List[tuple]:
        key = ast.dump(node)
        memo = env["__memo__"]
        if key in memo:
            return [(state, env, memo[key])]
        out = []
        for b in (True, False):
            e = dict(env)
            e["__memo__"] = dict(memo)
            e["__memo__"][key] = b
            out.append((state, e, b))
        return out

    # ---------------------------------------------------------- helpers
    def _members(self, state: tuple, v: tuple) -> List[int]:
        if v[0] == "queue":
            return [i for i, (_, qs) in enumerate(state)
                    if v[1] in qs]
        if v[0] == "union":
            got = {i for q in v[1]
                   for i, (_, qs) in enumerate(state) if q in qs}
            got.update(v[2])
            return sorted(got)
        if v[0] == "idset":
            return sorted(v[1])
        return []

    def _phase_const(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "Phase":
            return node.attr
        return None

    def _note_op(self, env: Dict, i: int, line: int) -> Dict:
        e = dict(env)
        e["__lastop__"] = dict(e["__lastop__"])
        e["__lastop__"][i] = line
        return e


def check_statemachine(ctx: FileContext) -> List[Violation]:
    """Model-check one scheduler file. Quiet unless the file defines a
    `SchedulerCore` class plus the PHASE_QUEUES / LIVE_QUEUES
    registries the abstraction is extracted from."""
    ex = _Extract(ctx.tree)
    if not ex.complete:
        return []
    return _Explorer(ctx, ex).run()
