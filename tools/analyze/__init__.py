"""repro-lint: the project's AST-based static-analysis framework.

Dependency-free (stdlib `ast` only, in the style of tools/check_docs.py)
so it runs anywhere — including a CI step before test deps install.

    python tools/analyze/run.py src        # lint the serving stack

See `core.py` for the runner/suppression machinery, `rules.py` for the
project-specific rules (PL001, JIT001, SEAM001, CFG001, PHASE001), and
docs/ARCHITECTURE.md "Invariants & analysis" for what each rule pins.
"""
