"""UNIT001 engine: unit-dimension taint analysis.

Propagates the `src/repro/core/units.py` dimension vocabulary
(`Blocks`, `Tokens`, `Bytes`, `LayerIdx`, `Seconds`) through the
project by AST dataflow and flags cross-dimension mixing that does not
go through a sanctioned converter. Every accounting bug fixed in PRs
2, 6 and 8 was exactly this shape: a token count compared against a
block count, bytes priced as tokens.

How it works, in two interprocedural passes over ALL linted files:

  pass 1  harvest dimension facts from annotations —
          * a signature table keyed by bare function/method name: the
            dimension (or None) of each positional parameter and the
            return. Conflicting duplicate names are merged field-wise:
            disagreeing facts degrade to "unknown" rather than guess;
          * an attribute table keyed by attribute name, from dataclass
            field / `self.x:` AnnAssigns and @property returns
            (e.g. `prompt_len` -> Tokens, `num_blocks` -> Blocks).

  pass 2  a flow-insensitive-per-branch, statement-ordered abstract
          interpretation of every function body. Names pick up
          dimensions from parameter annotations and assignments;
          expressions evaluate to a dimension or None (unknown).
          Violations fire ONLY when two KNOWN dimensions disagree —
          unknown never flags, so the analysis is quiet on undimmed
          code and grows teeth exactly as annotations spread.

Dimension algebra (deliberately conservative):

  a + b, a - b     both known and different -> violation; result is
                   the known side (addition preserves dimension)
  a * b, a / b     dimension-ERASING (a product of tokens and
                   bytes/token is bytes — only the annotated
                   converters know that), result unknown
  a // b, %        erasing as well (block arithmetic divides counts)
  a < b, a == b    known and different -> violation (ordering across
                   dimensions is the classic accounting bug)
  min/max(a, b)    two different known dims -> violation; else the
                   common known dimension survives
  sum(gen)         the element's dimension
  int()/float()/abs()/round()  pass the operand's dimension through
  f(a, b)          each KNOWN arg is checked against the parameter's
                   annotated dimension; the call evaluates to the
                   annotated return dimension

The sanctioned converters (`tokens_to_blocks`, `blocks_to_tokens`,
`tokens_to_bytes`, `blocks_to_bytes`, `bytes_to_seconds`, and any
annotated converting method such as `blocks_for_tokens`) need no
special-casing: their annotations — Tokens in, Blocks out — make them
the only paths that legally change a value's dimension.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set

try:
    from tools.analyze.core import FileContext, Violation
except ImportError:  # run as a plain script: tools/analyze on sys.path
    from core import FileContext, Violation

RULE_ID = "UNIT001"

DIMS = frozenset({"Blocks", "Tokens", "Bytes", "LayerIdx", "Seconds"})

# dims that may legally meet in + / - / comparisons with themselves
# only; everything else must route through a converter
_PASSTHROUGH_CALLS = frozenset({"int", "float", "abs", "round"})


def dim_of_annotation(node: Optional[ast.AST]) -> Optional[str]:
    """Dimension named by an annotation expression, or None.

    Recognizes a bare `Tokens`, a string literal `"Tokens"`,
    `Optional[Tokens]`, and `Tokens | None`.
    """
    if node is None:
        return None
    if isinstance(node, ast.Name):
        return node.id if node.id in DIMS else None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            return dim_of_annotation(
                ast.parse(node.value, mode="eval").body)
        except SyntaxError:
            return None
    if isinstance(node, ast.Subscript):
        base = node.value
        if isinstance(base, ast.Name) and base.id == "Optional":
            return dim_of_annotation(node.slice)
        return None
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        left = dim_of_annotation(node.left)
        right = dim_of_annotation(node.right)
        return left or right
    return None


@dataclasses.dataclass
class FuncSig:
    """Dimension view of one function: positional parameter dims (self
    already dropped for methods), keyword dims, return dim."""

    name: str
    params: List[Optional[str]]
    kwdims: Dict[str, Optional[str]]
    ret: Optional[str]
    check_params: bool = True  # False once duplicates disagree

    def merge(self, other: "FuncSig") -> None:
        if self.params != other.params or self.kwdims != other.kwdims:
            self.check_params = False
        if self.ret != other.ret:
            self.ret = None


def _sig_of(fn: ast.FunctionDef, is_method: bool) -> Optional[FuncSig]:
    args = fn.args
    pos = list(args.posonlyargs) + list(args.args)
    if is_method and pos and pos[0].arg in ("self", "cls"):
        pos = pos[1:]
    params = [dim_of_annotation(a.annotation) for a in pos]
    kwdims = {a.arg: dim_of_annotation(a.annotation)
              for a in pos + list(args.kwonlyargs)}
    ret = dim_of_annotation(fn.returns)
    if ret is None and not any(params) and not any(kwdims.values()):
        return None  # dimension-free function: nothing to say
    return FuncSig(fn.name, params, kwdims, ret)


class DimTables:
    """Pass-1 output: project-wide signature and attribute tables."""

    def __init__(self) -> None:
        self.sigs: Dict[str, FuncSig] = {}
        self.attrs: Dict[str, Optional[str]] = {}

    def add_sig(self, sig: FuncSig) -> None:
        have = self.sigs.get(sig.name)
        if have is None:
            self.sigs[sig.name] = sig
        else:
            have.merge(sig)

    def add_attr(self, name: str, dim: Optional[str]) -> None:
        if dim is None:
            return
        if name in self.attrs and self.attrs[name] != dim:
            self.attrs[name] = None  # ambiguous across classes: unknown
        else:
            self.attrs[name] = dim


def build_tables(ctxs: Sequence[FileContext]) -> DimTables:
    tables = DimTables()
    for ctx in ctxs:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, ast.AnnAssign) and isinstance(
                            item.target, ast.Name):
                        tables.add_attr(
                            item.target.id,
                            dim_of_annotation(item.annotation))
                    if isinstance(item, ast.FunctionDef):
                        sig = _sig_of(item, is_method=True)
                        if sig is not None:
                            tables.add_sig(sig)
                        if any(isinstance(d, ast.Name)
                               and d.id == "property"
                               for d in item.decorator_list):
                            tables.add_attr(
                                item.name,
                                dim_of_annotation(item.returns))
            elif isinstance(node, ast.FunctionDef):
                # module-level / nested defs (converters live here)
                sig = _sig_of(node, is_method=False)
                if sig is not None:
                    tables.add_sig(sig)
            elif isinstance(node, ast.AnnAssign) and isinstance(
                    node.target, ast.Attribute):
                # self.x: Dim = ...
                tables.add_attr(node.target.attr,
                                dim_of_annotation(node.annotation))
    return tables


_CMP_OPS = (ast.Lt, ast.LtE, ast.Gt, ast.GtE, ast.Eq, ast.NotEq)


class FunctionChecker:
    """Pass-2 walk of one function body with a name -> dim environment."""

    def __init__(self, ctx: FileContext, tables: DimTables,
                 fn: ast.FunctionDef) -> None:
        self.ctx = ctx
        self.tables = tables
        self.fn = fn
        self.env: Dict[str, Optional[str]] = {}
        self.out: List[Violation] = []
        self.ret_dim = dim_of_annotation(fn.returns)
        args = fn.args
        for a in (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs)):
            self.env[a.arg] = dim_of_annotation(a.annotation)

    # ------------------------------------------------------------ report
    def _flag(self, node: ast.AST, message: str) -> None:
        self.out.append(Violation(
            RULE_ID, self.ctx.path, node.lineno, message))

    # ------------------------------------------------------- expressions
    def dim_of(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name):
            return self.env.get(node.id)
        if isinstance(node, ast.Attribute):
            return self.tables.attrs.get(node.attr)
        if isinstance(node, ast.Constant):
            return None
        if isinstance(node, ast.UnaryOp):
            return self.dim_of(node.operand)
        if isinstance(node, ast.BinOp):
            return self._binop(node)
        if isinstance(node, ast.Compare):
            self._compare(node)
            return None
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.IfExp):
            self.dim_of(node.test)
            body = self.dim_of(node.body)
            other = self.dim_of(node.orelse)
            return body if body == other else (body or other) \
                if (body is None or other is None) else None
        if isinstance(node, ast.BoolOp):
            for v in node.values:
                self.dim_of(v)
            return None
        if isinstance(node, (ast.GeneratorExp, ast.ListComp,
                             ast.SetComp)):
            return self._comprehension(node)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set, ast.Dict,
                             ast.Subscript, ast.Starred, ast.Lambda,
                             ast.JoinedStr, ast.FormattedValue,
                             ast.NamedExpr, ast.Await)):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.expr):
                    self.dim_of(child)
            return None
        return None

    def _binop(self, node: ast.BinOp) -> Optional[str]:
        left = self.dim_of(node.left)
        right = self.dim_of(node.right)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            if left and right and left != right:
                op = "+" if isinstance(node.op, ast.Add) else "-"
                self._flag(node, self._mix_msg(left, op, right))
                return None
            return left or right
        # *, /, //, %: dimension-erasing (converters own those facts)
        return None

    def _compare(self, node: ast.Compare) -> None:
        prev = self.dim_of(node.left)
        prev_node: ast.AST = node.left
        for op, comp in zip(node.ops, node.comparators):
            cur = self.dim_of(comp)
            if isinstance(op, _CMP_OPS) and prev and cur \
                    and prev != cur:
                self._flag(prev_node, self._mix_msg(
                    prev, _cmp_symbol(op), cur))
            prev, prev_node = cur, comp

    def _comprehension(self, node: ast.AST) -> Optional[str]:
        saved = dict(self.env)
        for gen in node.generators:  # type: ignore[attr-defined]
            self.dim_of(gen.iter)
            for name in _target_names(gen.target):
                self.env[name] = None
            for cond in gen.ifs:
                self.dim_of(cond)
        if isinstance(node, ast.DictComp):
            self.dim_of(node.key)
            dim = self.dim_of(node.value)
        else:
            dim = self.dim_of(node.elt)  # type: ignore[attr-defined]
        self.env = saved
        return dim

    def _call(self, node: ast.Call) -> Optional[str]:
        name = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        elif isinstance(node.func, ast.Attribute):
            name = node.func.attr
        arg_dims = [self.dim_of(a) for a in node.args]
        kw_dims = {k.arg: self.dim_of(k.value)
                   for k in node.keywords if k.arg is not None}
        for k in node.keywords:
            if k.arg is None:
                self.dim_of(k.value)

        if name in ("min", "max"):
            known = [d for d in arg_dims if d]
            if len(set(known)) > 1:
                self._flag(node, self._mix_msg(
                    known[0], f"{name}()", known[1]))
                return None
            return known[0] if known else None
        if name == "sum" and node.args:
            return arg_dims[0]
        if name in _PASSTHROUGH_CALLS and node.args:
            return arg_dims[0]

        sig = self.tables.sigs.get(name) if name else None
        if sig is None:
            return None
        if sig.check_params:
            for i, (arg, dim) in enumerate(zip(node.args, arg_dims)):
                if isinstance(arg, ast.Starred) or i >= len(sig.params):
                    break
                want = sig.params[i]
                if dim and want and dim != want:
                    self._flag(arg, (
                        f"{dim} value passed to parameter "
                        f"{i + 1} of {sig.name}() annotated {want} "
                        f"(route through a units.py converter)"))
            for kw, dim in kw_dims.items():
                want = sig.kwdims.get(kw)
                if dim and want and dim != want:
                    self._flag(node, (
                        f"{dim} value passed to {sig.name}"
                        f"(...{kw}=) annotated {want} "
                        f"(route through a units.py converter)"))
        return sig.ret

    @staticmethod
    def _mix_msg(left: str, op: str, right: str) -> str:
        return (f"cross-dimension {left} {op} {right}: convert "
                f"explicitly (units.py sanctioned converters are the "
                f"only blessed casts)")

    # -------------------------------------------------------- statements
    def run(self) -> List[Violation]:
        self._block(self.fn.body)
        return self.out

    def _block(self, stmts: Sequence[ast.stmt]) -> None:
        for st in stmts:
            self._stmt(st)

    def _stmt(self, st: ast.stmt) -> None:
        if isinstance(st, ast.Assign):
            dim = self.dim_of(st.value)
            for tgt in st.targets:
                self._assign_target(tgt, dim, st)
        elif isinstance(st, ast.AnnAssign):
            ann = dim_of_annotation(st.annotation)
            dim = self.dim_of(st.value) if st.value is not None else None
            if ann and dim and ann != dim:
                self._flag(st, (
                    f"{dim} value bound to a name annotated {ann} "
                    f"(route through a units.py converter)"))
            if isinstance(st.target, ast.Name):
                self.env[st.target.id] = ann or dim
        elif isinstance(st, ast.AugAssign):
            dim = self.dim_of(st.value)
            if isinstance(st.op, (ast.Add, ast.Sub)):
                tdim = None
                if isinstance(st.target, ast.Name):
                    tdim = self.env.get(st.target.id)
                elif isinstance(st.target, ast.Attribute):
                    tdim = self.tables.attrs.get(st.target.attr)
                if tdim and dim and tdim != dim:
                    op = "+=" if isinstance(st.op, ast.Add) else "-="
                    self._flag(st, self._mix_msg(tdim, op, dim))
            elif isinstance(st.target, ast.Name):
                self.env[st.target.id] = None  # *=, //=: erased
        elif isinstance(st, ast.Return):
            if st.value is not None:
                dim = self.dim_of(st.value)
                if dim and self.ret_dim and dim != self.ret_dim:
                    self._flag(st, (
                        f"returns {dim} from a function annotated "
                        f"-> {self.ret_dim} (route through a units.py "
                        f"converter)"))
        elif isinstance(st, (ast.If, ast.While)):
            self.dim_of(st.test)
            self._block(st.body)
            self._block(st.orelse)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            self.dim_of(st.iter)
            for name in _target_names(st.target):
                self.env[name] = None
            self._block(st.body)
            self._block(st.orelse)
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                self.dim_of(item.context_expr)
            self._block(st.body)
        elif isinstance(st, ast.Try):
            self._block(st.body)
            for h in st.handlers:
                self._block(h.body)
            self._block(st.orelse)
            self._block(st.finalbody)
        elif isinstance(st, ast.Expr):
            self.dim_of(st.value)
        elif isinstance(st, (ast.Assert,)):
            self.dim_of(st.test)
        # nested defs/classes are visited as functions of their own

    def _assign_target(self, tgt: ast.AST, dim: Optional[str],
                       st: ast.stmt) -> None:
        if isinstance(tgt, ast.Name):
            self.env[tgt.id] = dim
        elif isinstance(tgt, ast.Attribute):
            want = self.tables.attrs.get(tgt.attr)
            if want and dim and want != dim:
                self._flag(st, (
                    f"{dim} value assigned to attribute "
                    f"'{tgt.attr}' annotated {want} "
                    f"(route through a units.py converter)"))
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                self._assign_target(el, None, st)


def _target_names(node: ast.AST) -> List[str]:
    names: List[str] = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            names.append(n.id)
    return names


def _cmp_symbol(op: ast.cmpop) -> str:
    return {ast.Lt: "<", ast.LtE: "<=", ast.Gt: ">", ast.GtE: ">=",
            ast.Eq: "==", ast.NotEq: "!="}[type(op)]


def check_units(ctxs: Sequence[FileContext]) -> List[Violation]:
    """Project-wide UNIT001 pass: build tables from ALL files, then
    dataflow-check every function body in every file."""
    tables = build_tables(ctxs)
    out: List[Violation] = []
    seen: Set[int] = set()
    for ctx in ctxs:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef) \
                    and id(node) not in seen:
                seen.add(id(node))
                out.extend(FunctionChecker(ctx, tables, node).run())
    return out
